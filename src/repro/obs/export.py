"""Trace/telemetry export: Chrome trace-event JSON + structured JSONL.

Two sinks:

- :func:`export_chrome_trace` renders recorded spans as Chrome
  trace-event format (the ``{"traceEvents": [...]}`` JSON object that
  ``chrome://tracing`` and https://ui.perfetto.dev load directly).  Each
  span becomes one complete ("ph": "X") event; fleet instances map to
  numbered pids with ``process_name`` metadata events so the frontend
  and every worker render as separate swim-lanes on ONE stitched
  timeline.  An optional fleet-metrics snapshot rides along under the
  top-level ``repro_metrics`` key (ignored by viewers, consumed by
  ``python -m repro.obs.report``).

- :class:`JsonlEventLog` appends one JSON object per line — the
  fit-telemetry format.  ``repro.stream`` fitters and
  ``repro.temporal.VersionedStore`` emit through the process-global
  :func:`fit_event` hook, which is a no-op unless a sink was installed
  (``set_fit_log(path)`` or ``REPRO_FIT_LOG=path``), so fitting pays
  nothing when telemetry is off.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import IO

from repro.obs.trace import Span, get_recorder


def chrome_trace_events(spans: list[Span], time_base: float | None = None) -> list[dict]:
    """Spans -> Chrome trace-event dicts (timestamps in microseconds,
    re-based so the earliest span starts at ``ts=0``)."""
    if time_base is None:
        time_base = min((s.t_start for s in spans), default=0.0)
    pids: dict[str, int] = {}
    events: list[dict] = []
    for s in spans:
        pid = pids.get(s.instance)
        if pid is None:
            pid = pids[s.instance] = len(pids) + 1
            events.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": s.instance},
            })
        events.append({
            "name": s.name,
            "cat": "repro",
            "ph": "X",
            "ts": round((s.t_start - time_base) * 1e6, 3),
            "dur": round(max(s.t_end - s.t_start, 0.0) * 1e6, 3),
            "pid": pid,
            "tid": pid,
            "args": dict(
                s.attrs,
                trace_id=f"{s.trace_id:x}",
                span_id=f"{s.span_id:x}",
                parent_id=f"{s.parent_id:x}",
            ),
        })
    return events


def export_chrome_trace(
    path: str,
    spans: list[Span] | None = None,
    metrics: dict | None = None,
) -> int:
    """Write a Chrome trace-event JSON file; returns the span count.
    ``spans`` defaults to a snapshot of the global recorder (buffer
    unchanged); ``metrics`` (any JSON-able dict, e.g. the fleet metrics
    roll-up's ``as_dict()``) is embedded under ``repro_metrics``."""
    if spans is None:
        spans = get_recorder().snapshot()
    doc: dict = {
        "traceEvents": chrome_trace_events(spans),
        "displayTimeUnit": "ms",
        "otherData": {"producer": "repro.obs", "spans": len(spans)},
    }
    if metrics is not None:
        doc["repro_metrics"] = metrics
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(spans)


# ---------------------------------------------------------------------------
# structured-event JSONL (fit telemetry)
# ---------------------------------------------------------------------------
class JsonlEventLog:
    """Append-only JSONL event sink; every ``emit`` is one flushed line,
    so a crashed fit leaves a readable prefix.

    ``max_bytes`` bounds the sink so a week-long ``fit_stream`` cannot
    fill the disk: a path-owned log ROTATES (``path`` -> ``path.1`` ->
    ... -> ``path.{backups}``, oldest dropped) and keeps writing, so the
    newest events always survive; a borrowed file object has nowhere to
    rotate to, so over-limit events are DROPPED and counted in
    ``events_dropped`` instead.  One event larger than the whole limit
    still rotates-then-writes (the alternative is losing it silently).
    Default is unbounded, matching the old behavior.
    """

    def __init__(
        self,
        path_or_file: str | IO[str],
        *,
        max_bytes: int | None = None,
        backups: int = 1,
    ):
        self.max_bytes = max_bytes
        self.backups = max(int(backups), 1)
        if isinstance(path_or_file, str):
            self._path: str | None = path_or_file
            self._f: IO[str] = open(path_or_file, "a")
            self._owns = True
            try:
                self._bytes = os.path.getsize(path_or_file)
            except OSError:
                self._bytes = 0
        else:
            self._path = None
            self._f = path_or_file
            self._owns = False
            self._bytes = 0
        self._lock = threading.Lock()
        self.events_written = 0
        self.events_dropped = 0
        self.rotations = 0

    @property
    def bytes_written(self) -> int:
        """Bytes in the CURRENT file (resets on rotation)."""
        return self._bytes

    def _rotate(self) -> None:
        self._f.close()
        for i in range(self.backups, 0, -1):
            src = self._path if i == 1 else f"{self._path}.{i - 1}"
            dst = f"{self._path}.{i}"
            if os.path.exists(src):
                os.replace(src, dst)
        self._f = open(self._path, "w")
        self._bytes = 0
        self.rotations += 1

    def emit(self, event: str, **fields) -> None:
        rec = {"event": event, "t": round(time.time(), 6), **fields}
        line = json.dumps(rec, default=float) + "\n"
        with self._lock:
            if (
                self.max_bytes is not None
                and self._bytes + len(line) > self.max_bytes
            ):
                if self._path is None:
                    self.events_dropped += 1
                    return
                self._rotate()
            self._f.write(line)
            self._f.flush()
            self._bytes += len(line)
            self.events_written += 1

    def close(self) -> None:
        with self._lock:
            if self._owns:
                self._f.close()

    def __enter__(self) -> "JsonlEventLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


_FIT_LOG: JsonlEventLog | None = None
_FIT_LOG_INIT = False
_FIT_LOCK = threading.Lock()


def _default_max_bytes() -> int | None:
    """Size bound for PATH-based global sinks: 64 MiB per file unless
    ``REPRO_FIT_LOG_MAX_BYTES`` overrides it (0 = unbounded)."""
    return int(os.environ.get("REPRO_FIT_LOG_MAX_BYTES", str(64 << 20))) or None


def set_fit_log(sink: str | IO[str] | JsonlEventLog | None) -> JsonlEventLog | None:
    """Install (or clear, with ``None``) the process-global fit-telemetry
    sink.  Returns the active log.  A path string gets the default size
    bound (see :func:`fit_log`); pass a :class:`JsonlEventLog` to choose
    your own."""
    global _FIT_LOG, _FIT_LOG_INIT
    with _FIT_LOCK:
        if _FIT_LOG is not None and sink is not _FIT_LOG:
            _FIT_LOG.close()
        if sink is None:
            _FIT_LOG = None
        elif isinstance(sink, JsonlEventLog):
            _FIT_LOG = sink
        elif isinstance(sink, str):
            _FIT_LOG = JsonlEventLog(sink, max_bytes=_default_max_bytes())
        else:
            _FIT_LOG = JsonlEventLog(sink)
        _FIT_LOG_INIT = True
    return _FIT_LOG


def fit_log() -> JsonlEventLog | None:
    """The active fit-telemetry sink, honoring ``REPRO_FIT_LOG`` on first
    use; ``None`` when telemetry is off.  Env-installed sinks are bounded
    (rotation at ``REPRO_FIT_LOG_MAX_BYTES``, default 64 MiB) so leaving
    telemetry on for a week cannot fill the disk."""
    global _FIT_LOG_INIT
    if not _FIT_LOG_INIT:
        with _FIT_LOCK:
            if not _FIT_LOG_INIT:
                path = os.environ.get("REPRO_FIT_LOG")
                if path:
                    globals()["_FIT_LOG"] = JsonlEventLog(
                        path, max_bytes=_default_max_bytes()
                    )
                globals()["_FIT_LOG_INIT"] = True
    return _FIT_LOG


def fit_telemetry_enabled() -> bool:
    """Cheap guard for call sites whose FIELD computation has a cost
    (e.g. forcing a device sync to read a loss scalar)."""
    return fit_log() is not None


def fit_event(event: str, **fields) -> None:
    """Emit one fit-telemetry event; no-op (one attribute read) when no
    sink is installed."""
    log = fit_log()
    if log is not None:
        log.emit(event, **fields)
