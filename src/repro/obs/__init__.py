"""``repro.obs`` — low-overhead tracing + metrics for the serving stack.

The paper's headline serving property (logarithmic per-entry
reconstruction, §4.4) only matters operationally if you can SEE where a
request spends its time.  This package threads spans through the whole
pipeline — ``FleetFrontend.decode_at`` → ``Transport`` wire →
``repro.fleet.worker`` → ``CodecService`` stages (``chunk_read``,
``materialize``, ``tile_decode``, ``prefetch_wait``, ``coalesce_flush``)
→ the fused ``kernel_decode`` — stitches worker spans back into one
cross-process trace, and exports Chrome trace-event JSON that Perfetto
loads directly.

    from repro import obs

    obs.enable_tracing()                      # or REPRO_TRACE=1
    fleet.decode_at("embed", idx)             # answers unchanged, bit-exact
    obs.export_chrome_trace("trace.json")
    # python -m repro.obs.report trace.json   # per-stage breakdown

Design contract: tracing and metrics are OBSERVATIONAL ONLY — answers
and every cache counter are bit-identical with tracing off or on, and a
disabled recorder allocates nothing per span (both asserted in CI).

Fit-time telemetry rides the same package: ``REPRO_FIT_LOG=fit.jsonl``
(or :func:`set_fit_log`) streams per-slab fit events (step, loss,
entries/sec, reservoir occupancy) and ``VersionedStore`` rekey decisions
as JSONL.
"""
from repro.obs.events import clear_events, emit_event, events
from repro.obs.export import (
    JsonlEventLog,
    chrome_trace_events,
    export_chrome_trace,
    fit_event,
    fit_log,
    fit_telemetry_enabled,
    set_fit_log,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_latency_buckets,
)
from repro.obs.trace import (
    Span,
    TraceRecorder,
    current_context,
    disable_tracing,
    enable_tracing,
    enabled,
    get_recorder,
    remote_context,
    span,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlEventLog",
    "MetricsRegistry",
    "Span",
    "TraceRecorder",
    "chrome_trace_events",
    "clear_events",
    "current_context",
    "default_latency_buckets",
    "disable_tracing",
    "emit_event",
    "enable_tracing",
    "enabled",
    "events",
    "export_chrome_trace",
    "fit_event",
    "fit_log",
    "fit_telemetry_enabled",
    "get_recorder",
    "remote_context",
    "set_fit_log",
    "span",
]
