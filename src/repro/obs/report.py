"""``python -m repro.obs.report`` — summarize an exported trace file.

Reads a Chrome trace-event JSON written by
:func:`repro.obs.export_chrome_trace` and prints:

- a per-stage time breakdown (total/mean/max wall time per span name,
  sorted by total) with each stage's share of the traced wall clock;
- the slowest individual spans (name, instance, duration, attrs);
- fleet cache hit rates, when the export embedded a metrics snapshot
  (the ``repro_metrics`` key ``fleet_bench --trace`` writes);
- with ``--fit events.jsonl``, a fit-telemetry summary (events per
  type, final loss, mean entries/sec).

    python -m repro.obs.report trace.json
    python -m repro.obs.report trace.json --top 5 --fit fit.jsonl
    python -m repro.obs.report trace.json --format json   # machine-readable

``--format json`` emits the same breakdown as one JSON object (stage
rows, slowest spans, embedded metrics snapshot, fit summary) so CI and
controller tests assert on parsed fields instead of scraping text.
"""
from __future__ import annotations

import argparse
import collections
import json
import sys


def load_trace(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome trace-event file "
                         "(missing 'traceEvents')")
    return doc


def stage_breakdown(events: list[dict]) -> list[dict]:
    """Aggregate complete ("X") events by span name."""
    by_name: dict[str, list[float]] = collections.defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            by_name[ev["name"]].append(float(ev.get("dur", 0.0)))
    rows = [
        {
            "stage": name,
            "count": len(durs),
            "total_ms": sum(durs) / 1e3,
            "mean_ms": sum(durs) / len(durs) / 1e3,
            "max_ms": max(durs) / 1e3,
        }
        for name, durs in by_name.items()
    ]
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def slowest_spans(events: list[dict], top: int) -> list[dict]:
    xs = [ev for ev in events if ev.get("ph") == "X"]
    xs.sort(key=lambda ev: -float(ev.get("dur", 0.0)))
    return xs[:top]


def _process_names(events: list[dict]) -> dict[int, str]:
    return {
        ev["pid"]: ev["args"]["name"]
        for ev in events
        if ev.get("ph") == "M" and ev.get("name") == "process_name"
    }


def summarize_fit(path: str) -> list[str]:
    counts: collections.Counter[str] = collections.Counter()
    last: dict[str, dict] = {}
    eps: list[float] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            counts[rec.get("event", "?")] += 1
            last[rec.get("event", "?")] = rec
            if "entries_per_sec" in rec:
                eps.append(float(rec["entries_per_sec"]))
    lines = [f"fit telemetry ({path}):"]
    for event, n in counts.most_common():
        tail = last[event]
        extras = []
        for key in ("step", "loss", "fitness", "reservoir_fill", "version",
                    "keyframe", "rekeyed", "rank"):
            if key in tail:
                v = tail[key]
                extras.append(f"{key}={v:.5g}" if isinstance(v, float) else f"{key}={v}")
        lines.append(f"  {event:<16} x{n:<6} last: {', '.join(extras) or '-'}")
    if eps:
        lines.append(f"  mean entries/sec: {sum(eps) / len(eps):,.0f}")
    return lines


def fit_summary_dict(path: str) -> dict:
    """Machine-readable fit-telemetry summary (the JSON analogue of
    :func:`summarize_fit`)."""
    counts: collections.Counter[str] = collections.Counter()
    last: dict[str, dict] = {}
    eps: list[float] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            event = rec.get("event", "?")
            counts[event] += 1
            last[event] = rec
            if "entries_per_sec" in rec:
                eps.append(float(rec["entries_per_sec"]))
    return {
        "path": path,
        "counts": dict(counts),
        "last": last,
        "mean_entries_per_sec": sum(eps) / len(eps) if eps else None,
    }


def report_dict(doc: dict, top: int) -> dict:
    """The whole report as one JSON-able object — what ``--format json``
    prints and what controller tests/CI assert on."""
    events = doc["traceEvents"]
    rows = stage_breakdown(events)
    names = _process_names(events)
    total = sum(r["total_ms"] for r in rows)
    return {
        "spans": sum(r["count"] for r in rows),
        "total_ms": total,
        "processes": sorted(names.values()),
        "stages": rows,
        "slowest": [
            {
                "stage": ev["name"],
                "instance": names.get(ev.get("pid"), str(ev.get("pid"))),
                "dur_ms": float(ev.get("dur", 0.0)) / 1e3,
                "args": {
                    k: v for k, v in ev.get("args", {}).items()
                    if k not in ("trace_id", "span_id", "parent_id")
                },
            }
            for ev in slowest_spans(events, top)
        ],
        "metrics": doc.get("repro_metrics"),
    }


def render(doc: dict, top: int) -> list[str]:
    events = doc["traceEvents"]
    rows = stage_breakdown(events)
    names = _process_names(events)
    lines: list[str] = []
    total = sum(r["total_ms"] for r in rows)
    n_spans = sum(r["count"] for r in rows)
    lines.append(
        f"{n_spans} spans, {len(rows)} stages, {len(names)} processes, "
        f"{total:.2f} ms total span time"
    )
    lines.append("")
    lines.append(f"{'stage':<20} {'count':>7} {'total ms':>10} "
                 f"{'mean ms':>9} {'max ms':>9} {'share':>7}")
    for r in rows:
        share = r["total_ms"] / total if total else 0.0
        lines.append(
            f"{r['stage']:<20} {r['count']:>7} {r['total_ms']:>10.2f} "
            f"{r['mean_ms']:>9.3f} {r['max_ms']:>9.3f} {share:>6.1%}"
        )
    lines.append("")
    lines.append(f"slowest {top} spans:")
    for ev in slowest_spans(events, top):
        who = names.get(ev.get("pid"), str(ev.get("pid")))
        args = {
            k: v for k, v in ev.get("args", {}).items()
            if k not in ("trace_id", "span_id", "parent_id")
        }
        lines.append(
            f"  {ev['name']:<20} {float(ev.get('dur', 0)) / 1e3:>9.3f} ms"
            f"  [{who}]  {args or ''}"
        )
    metrics = doc.get("repro_metrics")
    if metrics:
        lines.append("")
        lines.append("fleet cache hit rates:")
        fleet = metrics.get("fleet")
        if fleet:
            lines.append(
                f"  fleet     hits={fleet['hits']} misses={fleet['misses']} "
                f"hit_rate={fleet.get('hit_rate', 0):.3f}"
            )
        for iid, m in sorted(metrics.get("instances", {}).items()):
            c = m["cache"]
            lines.append(
                f"  {iid:<9} hits={c['hits']} misses={c['misses']} "
                f"hit_rate={c.get('hit_rate', 0):.3f} "
                f"p99_ms={m.get('decode_p99_ms')} "
                f"p99_ms_total={m.get('decode_p99_ms_total')}"
            )
        if metrics.get("excluded"):
            lines.append(f"  excluded: {', '.join(metrics['excluded'])}")
    return lines


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="summarize a Chrome trace-event file written by repro.obs",
    )
    parser.add_argument("trace", help="trace.json (Chrome trace-event format)")
    parser.add_argument("--top", type=int, default=10,
                        help="how many slowest spans to show (default 10)")
    parser.add_argument("--fit", default=None,
                        help="also summarize a fit-telemetry JSONL file")
    parser.add_argument("--format", choices=("text", "json"), default="text",
                        help="output format (json = machine-readable)")
    args = parser.parse_args(argv)

    try:
        doc = load_trace(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"repro.obs.report: {e}", file=sys.stderr)
        return 1
    if args.format == "json":
        out = report_dict(doc, args.top)
        if args.fit:
            out["fit"] = fit_summary_dict(args.fit)
        print(json.dumps(out, indent=2, default=float))
        return 0
    for line in render(doc, args.top):
        print(line)
    if args.fit:
        print()
        for line in summarize_fit(args.fit):
            print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
