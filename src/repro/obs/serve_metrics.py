"""``python -m repro.obs.serve_metrics`` — scrape endpoint for repro metrics.

:class:`MetricsServer` wraps any zero-argument PROVIDER returning
Prometheus text (usually a closure over :func:`render_exposition`) in a
threaded ``GET /metrics`` HTTP server — the piece that makes a live
fleet scrapeable:

    from repro import fleet as flt, obs
    from repro.obs.exposition import render_exposition
    from repro.obs.serve_metrics import MetricsServer

    srv = MetricsServer(
        lambda: render_exposition(
            fleet.metrics, fleet=flt.collect(fleet).as_dict()
        ),
        port=9091,
    )
    srv.start()          # GET http://127.0.0.1:9091/metrics

The CLI serves a SNAPSHOT file instead (a fleet ``as_dict`` JSON, a
``MetricsRegistry.as_dict`` JSON, or a trace export whose
``repro_metrics`` key embeds one) — rendered once per scrape, so a
dashboard can point at benchmark artifacts:

    python -m repro.obs.serve_metrics BENCH_fleet_snapshot.json --port 9091
    python -m repro.obs.serve_metrics trace.json --once   # print and exit
"""
from __future__ import annotations

import argparse
import http.server
import json
import sys
import threading
from typing import Callable

from repro.obs.exposition import render_exposition

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Threaded HTTP server answering ``GET /metrics`` (and ``/``) with
    whatever the provider returns; anything else is a 404."""

    def __init__(
        self,
        provider: Callable[[], str],
        host: str = "127.0.0.1",
        port: int = 0,
    ):
        self.provider = provider
        outer = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
                if self.path.split("?", 1)[0] not in ("/metrics", "/"):
                    self.send_error(404)
                    return
                try:
                    body = outer.provider().encode("utf-8")
                except Exception as e:  # noqa: BLE001 — scrape must not kill the server
                    self.send_error(500, f"{type(e).__name__}: {e}")
                    return
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # silence per-request stderr spam
                pass

        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self._thread: threading.Thread | None = None

    @property
    def address(self) -> tuple[str, int]:
        """(host, bound port) — port is concrete even when 0 was asked."""
        return self._httpd.server_address[:2]

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-metrics", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def _snapshot_provider(path: str) -> Callable[[], str]:
    """Classify a snapshot file by shape and build its render closure.
    Re-reads per scrape, so pointing at a file a benchmark rewrites
    live-updates the page."""

    def render() -> str:
        with open(path) as f:
            doc = json.load(f)
        if "traceEvents" in doc:  # a trace export; metrics ride inside
            fleet = doc.get("repro_metrics")
            if not fleet:
                raise ValueError(f"{path}: trace has no repro_metrics snapshot")
            return render_exposition(fleet=fleet)
        if "counters" in doc or "gauges" in doc or "histograms" in doc:
            return render_exposition(registry=doc)
        if "instances" in doc or "fleet" in doc:
            return render_exposition(fleet=doc)
        raise ValueError(
            f"{path}: not a fleet/registry/trace metrics snapshot"
        )

    return render


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro.obs.serve_metrics",
        description="serve a metrics snapshot file as a Prometheus endpoint",
    )
    parser.add_argument(
        "snapshot",
        help="fleet as_dict JSON, MetricsRegistry as_dict JSON, or a trace "
        "export with an embedded repro_metrics snapshot",
    )
    parser.add_argument("--host", default="127.0.0.1")
    parser.add_argument("--port", type=int, default=9091)
    parser.add_argument(
        "--once", action="store_true",
        help="print the exposition to stdout and exit (no server)",
    )
    args = parser.parse_args(argv)

    provider = _snapshot_provider(args.snapshot)
    if args.once:
        try:
            sys.stdout.write(provider())
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"repro.obs.serve_metrics: {e}", file=sys.stderr)
            return 1
        return 0
    srv = MetricsServer(provider, host=args.host, port=args.port)
    host, port = srv.address
    print(f"serving {args.snapshot} at http://{host}:{port}/metrics", flush=True)
    try:
        srv.start()
        threading.Event().wait()
    except KeyboardInterrupt:
        pass
    finally:
        srv.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
