"""Declarative SLOs evaluated over metric samples: hysteresis + burn rate.

An :class:`SLOSpec` names one objective over one metric key (``p99 decode
latency <= 5 ms``, ``per-payload canary fitness >= 0.95``) and the engine
turns a stream of flat metric samples into edge-triggered breach events:

- **streaks, not spikes** — a breach opens only after ``breach_for``
  CONSECUTIVE violating evaluations and closes only after ``clear_for``
  consecutive clearing ones, so one slow flush never flaps a controller;
- **hysteresis** — ``clear`` sets a recovery threshold tighter than the
  target (e.g. breach above 5 ms, clear below 4 ms).  Values between the
  two reset both streaks and HOLD the current state, which is what makes
  an autoscaler built on this engine oscillation-free by construction;
- **burn rate** — each series keeps a bounded window of violate/ok bits;
  ``burn_rate`` is the violating fraction, the "how fast is the error
  budget burning" signal dashboards alert on;
- **wildcards** — a metric key may contain ``*`` (``hit_rate.*``,
  ``canary_fitness.*``): every matching sample key gets its OWN series
  state, so per-instance and per-payload objectives are one spec line.

``None`` values (an instance with zero flushes yet) are skipped without
touching state — absence of signal is not a violation.

The engine is PURE: no clocks, no I/O, no emission — callers pass ``now``
and forward the returned events wherever they want (the fleet controller
mirrors them into ``repro.obs.events``).  That is what makes controller
decision logic testable over recorded fixtures.

    engine = SLOEngine([
        SLOSpec("latency", "decode_p99_ms", target=5.0, clear=4.0,
                breach_for=3, clear_for=2),
        SLOSpec("quality", "canary_fitness.*", target=0.9, op=">="),
    ])
    for sample in samples:
        for ev in engine.evaluate(sample, now=t):
            ...  # ev.kind is "breach_start" / "breach_end"
"""
from __future__ import annotations

import collections
import dataclasses
import fnmatch


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One objective: ``metric op target``, with hysteresis and streaks.

    ``op="<="`` means the metric must stay at or below ``target`` (latency
    style); ``op=">="`` at or above (fitness / hit-rate style).  ``clear``
    is the recovery threshold (defaults to ``target`` — no hysteresis
    band); it must be at least as strict as the target.
    """

    name: str
    metric: str
    target: float
    op: str = "<="
    clear: float | None = None
    breach_for: int = 1
    clear_for: int = 1
    #: burn-rate window in evaluations; default scales with breach_for
    window: int | None = None

    def __post_init__(self):
        if self.op not in ("<=", ">="):
            raise ValueError(f"slo {self.name!r}: op must be '<=' or '>='")
        if self.breach_for < 1 or self.clear_for < 1:
            raise ValueError(
                f"slo {self.name!r}: breach_for/clear_for must be >= 1"
            )
        if self.clear is not None:
            ok = (
                self.clear <= self.target
                if self.op == "<="
                else self.clear >= self.target
            )
            if not ok:
                raise ValueError(
                    f"slo {self.name!r}: clear={self.clear} is looser than "
                    f"target={self.target} under op {self.op!r}"
                )

    @property
    def burn_window(self) -> int:
        return self.window if self.window is not None else max(4 * self.breach_for, 8)

    def violates(self, value: float) -> bool:
        return value > self.target if self.op == "<=" else value < self.target

    def clears(self, value: float) -> bool:
        c = self.target if self.clear is None else self.clear
        return value <= c if self.op == "<=" else value >= c


@dataclasses.dataclass(frozen=True)
class SLOEvent:
    kind: str  # "breach_start" | "breach_end"
    slo: str
    metric: str  # the CONCRETE sample key (wildcards resolved)
    value: float
    threshold: float
    burn_rate: float
    at: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Series:
    """Per (spec, concrete-key) evaluation state."""

    __slots__ = ("bad", "good", "breached", "window")

    def __init__(self, window: int):
        self.bad = 0
        self.good = 0
        self.breached = False
        self.window: collections.deque[int] = collections.deque(maxlen=window)

    def burn_rate(self) -> float:
        return sum(self.window) / len(self.window) if self.window else 0.0


class SLOEngine:
    def __init__(self, specs: list[SLOSpec]):
        self.specs = list(specs)
        names = [s.name for s in self.specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate slo names: {sorted(names)}")
        self._series: dict[tuple[str, str], _Series] = {}

    def _keys(self, spec: SLOSpec, sample: dict) -> list[str]:
        if "*" not in spec.metric:
            return [spec.metric]
        return sorted(
            k for k in sample if fnmatch.fnmatchcase(k, spec.metric)
        )

    def evaluate(self, sample: dict, now: float = 0.0) -> list[SLOEvent]:
        """Feed one metric sample; returns edge events (state changes
        only — a breach that persists stays silent until it clears)."""
        events: list[SLOEvent] = []
        for spec in self.specs:
            for key in self._keys(spec, sample):
                value = sample.get(key)
                if value is None:
                    continue
                st = self._series.setdefault(
                    (spec.name, key), _Series(spec.burn_window)
                )
                violating = spec.violates(value)
                st.window.append(1 if violating else 0)
                if violating:
                    st.bad += 1
                    st.good = 0
                    if not st.breached and st.bad >= spec.breach_for:
                        st.breached = True
                        events.append(SLOEvent(
                            "breach_start", spec.name, key, float(value),
                            spec.target, st.burn_rate(), now,
                        ))
                elif spec.clears(value):
                    st.good += 1
                    st.bad = 0
                    if st.breached and st.good >= spec.clear_for:
                        st.breached = False
                        events.append(SLOEvent(
                            "breach_end", spec.name, key, float(value),
                            spec.target, st.burn_rate(), now,
                        ))
                else:  # hysteresis band: hold state, reset both streaks
                    st.bad = 0
                    st.good = 0
        return events

    def breached(self) -> list[tuple[str, str]]:
        """Currently-open breaches as (slo name, concrete metric key)."""
        return sorted(
            key for key, st in self._series.items() if st.breached
        )

    def is_breached(self, name: str, metric: str | None = None) -> bool:
        return any(
            st.breached
            for (n, k), st in self._series.items()
            if n == name and (metric is None or k == metric)
        )

    def burn_rate(self, name: str, metric: str) -> float:
        st = self._series.get((name, metric))
        return st.burn_rate() if st is not None else 0.0


def fleet_slo_sample(metrics, extra: dict | None = None) -> dict:
    """Flatten a fleet metrics snapshot (``repro.fleet.metrics.collect``'s
    :class:`FleetMetrics`, or its ``as_dict``) into the flat key space SLO
    specs address.  Duck-typed on ``as_dict`` so this module never imports
    the fleet layer."""
    d = metrics.as_dict() if hasattr(metrics, "as_dict") else dict(metrics)
    instances = d.get("instances", {})
    sample: dict = {
        "decode_p50_ms": d.get("decode_p50_ms"),
        "decode_p99_ms": d.get("decode_p99_ms"),
        "excluded_total": d.get("excluded_total", len(d.get("excluded", []))),
        "backpressure_flushes": d.get("backpressure_flushes", 0),
        "instances": len(instances),
        "flushes_total": sum(m.get("flushes", 0) for m in instances.values()),
    }
    for iid, m in instances.items():
        sample[f"hit_rate.{iid}"] = m.get("cache", {}).get("hit_rate")
    for payload, c in (d.get("canary") or {}).items():
        sample[f"canary_fitness.{payload}"] = c.get("rolling_fitness")
    if extra:
        sample.update(extra)
    return sample
