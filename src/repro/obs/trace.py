"""Ring-buffer span recorder: the tracing core of ``repro.obs``.

One process holds one global :class:`TraceRecorder` — a bounded deque of
finished :class:`Span` records.  ``span(name, **attrs)`` is the single
instrumentation primitive: a context manager that snapshots monotonic
start/end times and parents itself under the ambient (trace id, span id)
context, which propagates through nested ``with`` blocks via a
``contextvars.ContextVar`` (thread- and task-correct).

Cost model — the whole point of this module:

- **disabled** (the default): ``span()`` returns one shared no-op
  context manager.  No ``Span`` object, no recorder append, no id
  allocation — the recorder's ``span_allocs`` counter observably stays
  flat, which ``tests/test_obs.py`` asserts.
- **enabled** (``REPRO_TRACE=1`` or :func:`enable_tracing`): one small
  object + two ``perf_counter`` calls per span, appended to a
  ``maxlen``-bounded deque, so memory is capped no matter how long the
  process serves.

Cross-process stitching: a worker adopts the frontend's (trace id,
span id) via :func:`remote_context`, records its spans against ITS
monotonic clock, and ships them back in the flush reply; the frontend
calls :meth:`TraceRecorder.ingest` with a clock offset so every span in
the buffer lives on one frontend timeline.  Tracing never touches decode
inputs or cache counters — answers are bit-identical on or off.
"""
from __future__ import annotations

import collections
import contextlib
import contextvars
import dataclasses
import itertools
import os
import time

#: ambient (trace_id, span_id) the next span parents under; None = new trace
_CTX: contextvars.ContextVar[tuple[int, int] | None] = contextvars.ContextVar(
    "repro_obs_ctx", default=None
)

#: default ring capacity (spans); REPRO_TRACE_CAPACITY overrides
DEFAULT_CAPACITY = 16384


@dataclasses.dataclass(slots=True)
class Span:
    """One finished span: half-open ``[t_start, t_end)`` on the recording
    process's monotonic clock (seconds)."""

    name: str
    trace_id: int
    span_id: int
    parent_id: int  # 0 = root of its trace
    t_start: float
    t_end: float
    attrs: dict
    #: which fleet member recorded it ("frontend" unless ingested)
    instance: str = "frontend"

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


class _NoopSpan:
    """The shared disabled-path context manager: allocation-free entry."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NOOP = _NoopSpan()


class _LiveSpan:
    """An open span: created by ``TraceRecorder.span`` when enabled."""

    __slots__ = ("_rec", "_span", "_token")

    def __init__(self, rec: "TraceRecorder", name: str, attrs: dict):
        self._rec = rec
        parent = _CTX.get()
        sid = next(rec._ids)
        if parent is None:
            tid, pid = rec.new_trace_id(), 0
        else:
            tid, pid = parent[0], parent[1]
        self._span = Span(name, tid, sid, pid, 0.0, 0.0, attrs, rec.service)
        rec.span_allocs += 1

    def __enter__(self) -> Span:
        self._token = _CTX.set((self._span.trace_id, self._span.span_id))
        self._span.t_start = time.perf_counter()
        return self._span

    def __exit__(self, exc_type, exc, tb) -> None:
        self._span.t_end = time.perf_counter()
        _CTX.reset(self._token)
        if exc_type is not None:
            self._span.attrs = dict(self._span.attrs, error=exc_type.__name__)
        self._rec._append(self._span)


class TraceRecorder:
    """Bounded in-memory span store for one process."""

    def __init__(self, capacity: int | None = None, service: str = "frontend"):
        if capacity is None:
            capacity = int(os.environ.get("REPRO_TRACE_CAPACITY", DEFAULT_CAPACITY))
        self.capacity = capacity
        self.service = service
        self.enabled = False
        # no lock: deque append/copy/clear/popleft are single C calls, so
        # they are atomic under the GIL — instance-executor threads record
        # concurrently without contending on anything
        self._spans: collections.deque[Span] = collections.deque(maxlen=capacity)
        # span ids are process-unique; trace ids additionally fold in the
        # pid so two processes opening traces concurrently cannot collide
        self._ids = itertools.count(1)
        self._trace_base = (os.getpid() & 0xFFFFF) << 40
        #: Span objects ever created — the disabled path must keep this
        #: flat (asserted by the zero-allocation smoke test)
        self.span_allocs = 0
        #: spans dropped by the ring bound (admission is never blocked)
        self.dropped = 0

    # -- recording ----------------------------------------------------------
    def span(self, name: str, **attrs):
        """Start a span (context manager).  Returns the shared no-op when
        the recorder is disabled — zero allocations on the hot path."""
        if not self.enabled:
            return _NOOP
        return _LiveSpan(self, name, attrs)

    def new_trace_id(self) -> int:
        return self._trace_base | next(self._ids)

    def _append(self, s: Span) -> None:
        if len(self._spans) == self._spans.maxlen:
            self.dropped += 1  # the bounded deque evicts the oldest span
        self._spans.append(s)

    # -- reading ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._spans)

    def snapshot(self) -> list[Span]:
        """Copy of the buffered spans, oldest first (buffer unchanged)."""
        return list(self._spans)

    def drain(self) -> list[Span]:
        """Pop every buffered span — what a worker ships in a flush reply.
        Pops one at a time so spans recorded concurrently (e.g. by a
        prefetch thread) are either drained or left for the next drain,
        never lost."""
        out = []
        try:
            while True:
                out.append(self._spans.popleft())
        except IndexError:
            return out

    def clear(self) -> None:
        self._spans.clear()

    def ingest(
        self, spans: list[Span], *, clock_offset: float = 0.0,
        instance: str | None = None,
    ) -> None:
        """Stitch spans recorded on ANOTHER process's clock into this
        buffer: ``clock_offset`` (this process's ``perf_counter`` minus the
        remote one, sampled at reply time) re-bases their timestamps onto
        the local timeline; ``instance`` labels who recorded them."""
        for s in spans:
            if clock_offset:
                s = dataclasses.replace(
                    s, t_start=s.t_start + clock_offset, t_end=s.t_end + clock_offset
                )
            if instance is not None:
                s = dataclasses.replace(s, instance=instance)
            self._append(s)


# ---------------------------------------------------------------------------
# the process-global recorder
# ---------------------------------------------------------------------------
_RECORDER = TraceRecorder()
_RECORDER.enabled = os.environ.get("REPRO_TRACE", "") not in ("", "0")


def get_recorder() -> TraceRecorder:
    return _RECORDER


def enabled() -> bool:
    return _RECORDER.enabled


def enable_tracing(capacity: int | None = None) -> TraceRecorder:
    """Turn the global recorder on (idempotent).  ``capacity`` resizes the
    ring, dropping buffered spans."""
    if capacity is not None and capacity != _RECORDER.capacity:
        _RECORDER.capacity = capacity
        _RECORDER._spans = collections.deque(maxlen=capacity)
    _RECORDER.enabled = True
    return _RECORDER


def disable_tracing() -> None:
    _RECORDER.enabled = False


def span(name: str, **attrs):
    """Module-level convenience over the global recorder — THE primitive
    every instrumentation point in the repo calls."""
    rec = _RECORDER
    if not rec.enabled:
        return _NOOP
    return _LiveSpan(rec, name, attrs)


def current_context() -> tuple[int, int] | None:
    """The ambient (trace id, span id), for wire propagation."""
    return _CTX.get()


def remote_context(ctx: tuple[int, int] | None):
    """Adopt a (trace id, span id) shipped from another process so local
    spans stitch under the remote parent; ``None`` is a no-op."""
    if ctx is None:
        return contextlib.nullcontext()
    return _AdoptedContext(ctx)


class _AdoptedContext:
    __slots__ = ("_ctx", "_token")

    def __init__(self, ctx: tuple[int, int]):
        self._ctx = ctx

    def __enter__(self) -> None:
        self._token = _CTX.set(self._ctx)

    def __exit__(self, *exc) -> None:
        _CTX.reset(self._token)
