"""Operational event stream: a bounded in-process buffer + the JSONL sink.

Spans answer "where did the time go"; EVENTS answer "what did the system
decide" — a canary fitness breach, a controller scale-up, an instance
exclusion.  :func:`emit_event` appends to a bounded ring buffer (cheap,
always on, never grows) and forwards to the process-global fit-telemetry
sink when one is installed (``set_fit_log`` / ``REPRO_FIT_LOG``), so the
same JSONL file carries fit progress and serve-time decisions.

Tests and the fleet controller read the buffer back with
:func:`events`; it is a diagnostic window, not a durable queue — old
events fall off the end once ``maxlen`` is reached.
"""
from __future__ import annotations

import collections
import threading
import time

from repro.obs import export as _export

#: ring-buffer capacity; oldest events are dropped beyond this
BUFFER_EVENTS = 1024

_BUFFER: collections.deque = collections.deque(maxlen=BUFFER_EVENTS)
_LOCK = threading.Lock()


def emit_event(kind: str, **fields) -> dict:
    """Record one operational event; returns the event dict.  Buffered
    in-process always; mirrored to the fit-telemetry JSONL sink when one
    is installed."""
    ev = {"event": str(kind), "t": round(time.time(), 6), **fields}
    with _LOCK:
        _BUFFER.append(ev)
    log = _export.fit_log()
    if log is not None:
        log.emit(ev["event"], **{k: v for k, v in ev.items() if k not in ("event", "t")})
    return ev


def events(kind: str | None = None) -> list[dict]:
    """Snapshot of the buffered events, oldest first, optionally filtered
    by kind."""
    with _LOCK:
        evs = list(_BUFFER)
    if kind is None:
        return evs
    return [e for e in evs if e["event"] == kind]


def clear_events() -> None:
    with _LOCK:
        _BUFFER.clear()
