"""Prometheus text exposition (format 0.0.4) for repro metrics.

Renders three source shapes into one scrapeable page:

- a live :class:`~repro.obs.metrics.MetricsRegistry` — counters and
  gauges verbatim, histograms as FULL Prometheus histograms (cumulative
  ``_bucket{le=...}`` series from the fixed log buckets, ``+Inf``,
  ``_sum``, ``_count``), so a scraper can compute any quantile with
  ``histogram_quantile``;
- a registry SNAPSHOT dict (``MetricsRegistry.as_dict()`` — what rides a
  trace export or a wire stats blob) — histograms collapse to
  summary-style ``{quantile="..."}`` series, because bucket counts do not
  ride the snapshot;
- a fleet metrics snapshot (``repro.fleet.metrics.collect().as_dict()``)
  — ``repro_fleet_*`` gauges with ``instance``/``payload`` labels.

Everything feeds :func:`render_exposition`; ``python -m
repro.obs.serve_metrics`` serves it over HTTP.  Metric and label names
are sanitized to the Prometheus charset; values render with ``repr``
(full float precision).
"""
from __future__ import annotations

import math
import re

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_OK = re.compile(r"[^a-zA-Z0-9_]")


def _name(s: str) -> str:
    s = _NAME_OK.sub("_", str(s))
    return s if not s or not s[0].isdigit() else "_" + s


def _label_key(s: str) -> str:
    s = _LABEL_OK.sub("_", str(s))
    return s if not s or not s[0].isdigit() else "_" + s


def _escape(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels(labels: dict, extra: dict | None = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(
        f'{_label_key(k)}="{_escape(v)}"' for k, v in sorted(merged.items())
    )
    return "{" + inner + "}"


def _num(v) -> str:
    if v is None:
        return "NaN"
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f) if isinstance(v, float) else str(v)


def _render_histogram(lines: list[str], h: Histogram) -> None:
    name = _name(h.name)
    labels = dict(h.labels)
    lines.append(f"# TYPE {name} histogram")
    cum = 0
    for bound, count in zip(h.bounds, h.bucket_counts):
        cum += count
        lines.append(
            f"{name}_bucket{_labels(labels, {'le': _num(float(bound))})} {cum}"
        )
    lines.append(f"{name}_bucket{_labels(labels, {'le': '+Inf'})} {h.count}")
    lines.append(f"{name}_sum{_labels(labels)} {_num(h.total)}")
    lines.append(f"{name}_count{_labels(labels)} {h.count}")


def _render_registry(lines: list[str], registry: MetricsRegistry) -> None:
    typed: set[str] = set()
    for inst in registry.instruments():
        name = _name(inst.name)
        if isinstance(inst, Counter):
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} counter")
            lines.append(f"{name}{_labels(dict(inst.labels))} {inst.value}")
        elif isinstance(inst, Gauge):
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name}{_labels(dict(inst.labels))} {_num(inst.value)}")
        elif isinstance(inst, Histogram):
            _render_histogram(lines, inst)


def _render_registry_snapshot(lines: list[str], snap: dict) -> None:
    """A ``MetricsRegistry.as_dict()`` snapshot: bucket counts are gone,
    so histograms render as summary quantile series instead."""
    for c in snap.get("counters", []):
        name = _name(c["name"])
        lines.append(f"# TYPE {name} counter")
        lines.append(f"{name}{_labels(c.get('labels', {}))} {c['value']}")
    for g in snap.get("gauges", []):
        name = _name(g["name"])
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name}{_labels(g.get('labels', {}))} {_num(g['value'])}")
    for h in snap.get("histograms", []):
        name = _name(h["name"])
        labels = h.get("labels", {})
        lines.append(f"# TYPE {name} summary")
        for q, key in ((0.5, "p50"), (0.99, "p99")):
            if h.get(key) is not None:
                lines.append(
                    f"{name}{_labels(labels, {'quantile': q})} {_num(h[key])}"
                )
        lines.append(f"{name}_sum{_labels(labels)} {_num(h.get('sum', 0.0))}")
        lines.append(f"{name}_count{_labels(labels)} {h.get('count', 0)}")


def _render_fleet(lines: list[str], fleet: dict) -> None:
    """``repro.fleet.metrics.collect().as_dict()`` -> repro_fleet_* series."""
    def gauge(name: str, value, labels: dict | None = None) -> None:
        if value is None:
            return
        lines.append(f"repro_fleet_{name}{_labels(labels or {})} {_num(value)}")

    f = fleet.get("fleet", {})
    for key in ("hits", "misses", "evictions", "resident_bytes", "hit_rate"):
        gauge(f"cache_{key}", f.get(key))
    gauge("backpressure_flushes", fleet.get("backpressure_flushes"))
    gauge("excluded", len(fleet.get("excluded", [])))
    gauge("excluded_total", fleet.get("excluded_total"))
    gauge("instances", len(fleet.get("instances", {})))
    gauge("decode_p50_ms", fleet.get("decode_p50_ms"))
    gauge("decode_p99_ms", fleet.get("decode_p99_ms"))
    for payload, c in sorted(fleet.get("canary", {}).items()):
        lbl = {"payload": payload}
        gauge("canary_checks", c.get("checks"), lbl)
        gauge("canary_breaches", c.get("breaches"), lbl)
        gauge("canary_fitness", c.get("rolling_fitness"), lbl)
    for iid, m in sorted(fleet.get("instances", {}).items()):
        lbl = {"instance": iid}
        cache = m.get("cache", {})
        for key in ("hits", "misses", "evictions", "resident_bytes", "hit_rate"):
            gauge(f"instance_cache_{key}", cache.get(key), lbl)
        gauge("instance_decode_p50_ms", m.get("decode_p50_ms"), lbl)
        gauge("instance_decode_p99_ms", m.get("decode_p99_ms"), lbl)
        gauge("instance_flushes", m.get("flushes"), lbl)
        gauge("instance_peak_inflight_bytes", m.get("peak_inflight_bytes"), lbl)


def render_exposition(
    registry: MetricsRegistry | dict | None = None,
    fleet: dict | None = None,
) -> str:
    """Render metrics as Prometheus text format 0.0.4.  ``registry`` may
    be a live :class:`MetricsRegistry` or its ``as_dict()`` snapshot;
    ``fleet`` a fleet metrics snapshot dict.  Either or both."""
    lines: list[str] = []
    if isinstance(registry, MetricsRegistry):
        _render_registry(lines, registry)
    elif isinstance(registry, dict):
        _render_registry_snapshot(lines, registry)
    if fleet is not None:
        _render_fleet(lines, fleet)
    return "\n".join(lines) + ("\n" if lines else "")
